"""Stoch-IMC memory-architecture model (Section 4-3, Fig. 8).

An [n, m] bank: ``n`` groups x ``m`` subarrays (square layout, n == m in the
paper's evaluation, 256x256-cell subarrays).  Bit-parallelism: bit ``i`` of
the application bitstream executes in subarray ``i``; when the bitstream is
longer than n*m*q (q bits per subarray), the bank either *pipelines*
(sequential passes, minimum area — the paper's evaluation choice) or
*parallelizes* over more banks.

Stochastic->binary accumulation is hierarchical: m-step local accumulation in
every group (in parallel), then n-step global accumulation: n + m steps
instead of the n*m of an ungrouped organization (validated in
tests/test_arch.py against the paper's 32-vs-256-step example).

This model turns a Schedule (one subarray's cycle/energy/write accounting)
into application-level totals: cycles, energy breakdown (Fig. 10), lifetime
proxies (Eq. 11).
"""
from __future__ import annotations

import dataclasses
import math

from . import energy as energy_model
from .gates import Netlist
from .scheduler import Schedule, input_init_cycles


@dataclasses.dataclass(frozen=True)
class StochIMCConfig:
    """[n, m] configuration (defaults = the paper's evaluation setup)."""

    n_groups: int = 16
    m_subarrays: int = 16
    subarray_rows: int = 256
    subarray_cols: int = 256
    n_banks: int = 1
    bitstream_length: int = 256      # 8-bit resolution
    mode: str = "pipeline"           # "pipeline" | "parallel" (Section 4-3)

    @property
    def subarrays_per_bank(self) -> int:
        return self.n_groups * self.m_subarrays

    def accumulation_steps(self) -> int:
        """n + m hierarchical accumulation (vs n*m ungrouped)."""
        return self.n_groups + self.m_subarrays

    def accumulation_steps_ungrouped(self) -> int:
        return self.n_groups * self.m_subarrays


@dataclasses.dataclass
class AppCost:
    """Application-level totals for one method (one full evaluation)."""

    method: str
    total_cycles: int
    logic_cycles: int
    init_cycles: int
    accumulation_cycles: int
    n_passes: int
    energy: energy_model.EnergyBreakdown
    cells_used: int                  # distinct cells across all subarrays
    subarray_rows: int
    subarray_cols: int
    cell_writes: int                 # total write events (lifetime, Eq. 11)

    @property
    def total_energy_j(self) -> float:
        return self.energy.total_j

    def lifetime_proxy(self) -> float:
        """Eq. (11) with utilized cells: lifetime ∝ cells_used / writes-per-cell
        = cells_used^2 / total_writes . . . normalized across methods as
        (cells_used / cell_writes) — see lifetime_improvement()."""
        return self.cells_used / max(self.cell_writes, 1)


def evaluate_stoch_imc(net: Netlist, sch: Schedule, cfg: StochIMCConfig,
                       n_instances: int = 1) -> AppCost:
    """Cost of executing a scheduled stochastic netlist on the architecture.

    ``sch`` must have been produced with ``n_lanes = q * instances_per_pass``;
    the subarray handles ``sch.n_lanes`` lanes per pass.  The total lane
    demand is ``bitstream_length * n_instances``; lanes are spread across the
    n*m subarrays and, beyond that, across sequential passes (pipeline mode)
    or extra banks (parallel mode).
    """
    total_lanes = cfg.bitstream_length * n_instances
    lanes_per_pass = sch.n_lanes * cfg.subarrays_per_bank * cfg.n_banks
    n_passes = math.ceil(total_lanes / lanes_per_pass)

    init = input_init_cycles(net)
    per_pass_cycles = sch.total_cycles(init_cycles=init)
    acc_cycles = cfg.accumulation_steps()

    if cfg.mode == "pipeline":
        compute_cycles = per_pass_cycles * n_passes
    else:  # parallel across banks: passes collapse, plus transfer overhead
        compute_cycles = per_pass_cycles + 2  # global-bus transfer cycles
    total_cycles = compute_cycles + acc_cycles

    active_subarrays = min(math.ceil(total_lanes / sch.n_lanes),
                           cfg.subarrays_per_bank * cfg.n_banks)
    comp = energy_model.computation_energy(sch, stochastic=True)
    # Each subarray executes the schedule once per pass it participates in.
    per_subarray_passes = math.ceil(total_lanes / (sch.n_lanes * active_subarrays))
    scale = active_subarrays * per_subarray_passes
    groups_active = math.ceil(active_subarrays / cfg.m_subarrays)
    peripheral = energy_model.peripheral_energy(
        active_subarrays, groups_active, sch.logic_cycles, sch.n_cols,
        n_local_acc_steps=cfg.m_subarrays, n_global_acc_steps=cfg.n_groups,
        stochastic=True)
    breakdown = energy_model.EnergyBreakdown(
        logic_j=comp.logic_j * scale,
        preset_j=comp.preset_j * scale,
        input_init_j=comp.input_init_j * scale,
        peripheral_j=peripheral * per_subarray_passes,
    )
    return AppCost(
        method="stoch-imc",
        total_cycles=total_cycles,
        logic_cycles=sch.logic_cycles * (n_passes if cfg.mode == "pipeline" else 1),
        init_cycles=init * (n_passes if cfg.mode == "pipeline" else 1),
        accumulation_cycles=acc_cycles,
        n_passes=n_passes,
        energy=breakdown,
        cells_used=sch.cells_used * active_subarrays,
        subarray_rows=sch.n_rows,
        subarray_cols=sch.n_cols,
        cell_writes=sch.cell_writes * scale,
    )


def evaluate_binary_imc(net: Netlist, sch: Schedule, cfg: StochIMCConfig,
                        n_instances: int = 1) -> AppCost:
    """Cost of the binary 2T-1MTJ baseline [3, 8] for the same computation.

    Binary IMC executes one (multi-bit) instance per subarray region; the
    intra-subarray-parallel implementation packs as many instances as rows
    allow, then iterates.
    """
    init = input_init_cycles(net)
    instances_per_subarray = max(cfg.subarray_rows // max(sch.n_rows, 1), 1)
    lanes_per_pass = instances_per_subarray * cfg.subarrays_per_bank * cfg.n_banks
    n_passes = math.ceil(n_instances / lanes_per_pass)
    per_pass_cycles = sch.total_cycles(init_cycles=init)
    total_cycles = per_pass_cycles * n_passes

    active_subarrays = min(math.ceil(n_instances / instances_per_subarray),
                           cfg.subarrays_per_bank * cfg.n_banks)
    comp = energy_model.computation_energy(sch, stochastic=False)
    scale = n_instances  # each instance executes the netlist once
    peripheral = energy_model.peripheral_energy(
        active_subarrays, math.ceil(active_subarrays / cfg.m_subarrays),
        sch.logic_cycles, sch.n_cols,
        n_local_acc_steps=0, n_global_acc_steps=0, stochastic=False)
    breakdown = energy_model.EnergyBreakdown(
        logic_j=comp.logic_j * scale,
        preset_j=comp.preset_j * scale,
        input_init_j=comp.input_init_j * scale,
        peripheral_j=peripheral * n_passes,
    )
    return AppCost(
        method="binary-imc",
        total_cycles=total_cycles,
        logic_cycles=sch.logic_cycles * n_passes,
        init_cycles=init * n_passes,
        accumulation_cycles=0,
        n_passes=n_passes,
        energy=breakdown,
        cells_used=sch.cells_used * min(n_instances, active_subarrays * instances_per_subarray),
        subarray_rows=sch.n_rows * min(instances_per_subarray, n_instances),
        subarray_cols=sch.n_cols,
        cell_writes=sch.cell_writes * scale,
    )


def evaluate_sc_cram(net: Netlist, sch_1lane: Schedule, cfg: StochIMCConfig,
                     n_instances: int = 1) -> AppCost:
    """Cost model of the in-memory SC method of [22] (SC-CRAM).

    Per the paper's related-work discussion: bit-serial — the per-bit
    stochastic circuit executes once per bitstream bit *sequentially in a
    single subarray* ("computations for each bit are presented and repeated
    according to the bitstream length"; "relies on a single memory array").
    No result-accumulation architecture is provided, so StoB conversion is
    done by a serial counter over the bitstream (BL steps).
    """
    init = input_init_cycles(net)
    per_bit_cycles = sch_1lane.total_cycles(init_cycles=init)
    bl = cfg.bitstream_length
    total_cycles = per_bit_cycles * bl * n_instances + bl  # + serial count
    comp = energy_model.computation_energy(sch_1lane, stochastic=True)
    scale = bl * n_instances
    peripheral = energy_model.peripheral_energy(
        1, 1, sch_1lane.logic_cycles * bl, sch_1lane.n_cols,
        n_local_acc_steps=bl, n_global_acc_steps=0, stochastic=True)
    # [22] has no accumulator hierarchy: its StoB is a serial counter; we
    # charge it the local-accumulator energy per bit (already in the call).
    breakdown = energy_model.EnergyBreakdown(
        logic_j=comp.logic_j * scale,
        preset_j=comp.preset_j * scale,
        input_init_j=comp.input_init_j * scale,
        peripheral_j=peripheral * n_instances,
    )
    return AppCost(
        method="sc-cram[22]",
        total_cycles=total_cycles,
        logic_cycles=sch_1lane.logic_cycles * scale,
        init_cycles=init * scale,
        accumulation_cycles=bl,
        n_passes=scale,
        energy=breakdown,
        cells_used=sch_1lane.cells_used,   # single subarray, cells reused
        subarray_rows=sch_1lane.n_rows,
        subarray_cols=sch_1lane.n_cols,
        cell_writes=sch_1lane.cell_writes * scale,
    )


@dataclasses.dataclass(frozen=True)
class BankPlanCost:
    """Cycle accounting for a bank-merged plan vs a per-member dispatch loop.

    For padded bank templates (``plan.compile_bank_template``), the active-vs-
    padded split keeps the model honest: ``active_passes`` is what a bank
    merging exactly the bound members would execute, and the padding overhead
    fields price the extra passes the padded slots drag along.
    """

    n_members: int
    merged_passes: int           # fused passes of the merged (padded) plan
    looped_passes: int           # sum of active members' own plan passes
    pipeline_factor: int         # sequential bank passes to cover BL lanes
    accumulation_cycles: int     # n + m hierarchical StoB steps
    merged_cycles: int
    looped_cycles: int
    active_members: int = -1     # bound slots (excl. padding / identity)
    active_passes: int = -1      # passes of an exact-fit merged bank
    padding_overhead_passes: int = 0
    padding_overhead_cycles: int = 0
    #: Algorithm-1 scheduled cycles of the merged bank: the comb/seq group
    #: plans' actual row/lane schedules (logic cycles + final read + SBG
    #: input-initialization), pipelined and accumulated like merged_cycles.
    #: Richer than the pass-count arithmetic above (which stays as-is — its
    #: invariants are pinned): scheduling can overlap presets and must
    #: serialize BUFF copies, so the two cycle counts legitimately differ.
    schedule_cycles: int = 0
    #: Same pricing for a per-active-member dispatch loop (each member's own
    #: schedule + init, one accumulation hierarchy per dispatch).
    looped_schedule_cycles: int = 0
    #: Peak simultaneously-live node streams across the bank's group plans
    #: (the compiler liveness stage's scratch high-water mark) and the naive
    #: one-row-per-node count it replaces.  Live streams occupy subarray rows
    #: for the duration of a pass wave, so ``max_live`` — not node count — is
    #: what bounds how many instances share a subarray.
    max_live: int = 0
    naive_live: int = 0
    #: ``max_live`` as a fraction of one subarray's rows (> 1.0 means the
    #: bank's wave spills across subarrays even with liveness-driven reuse).
    live_occupancy_frac: float = 0.0

    @property
    def simd_speedup(self) -> float:
        return self.looped_cycles / max(self.merged_cycles, 1)

    @property
    def live_reduction(self) -> float:
        """Row-footprint shrink from liveness-driven reuse (naive / peak)."""
        return self.naive_live / max(self.max_live, 1)

    @property
    def schedule_speedup(self) -> float:
        """SIMD speedup per the Algorithm-1 schedules (vs raw pass counts)."""
        return self.looped_schedule_cycles / max(self.schedule_cycles, 1)

    @property
    def padding_overhead_frac(self) -> float:
        """Fraction of merged bank cycles spent on padded-slot passes."""
        return self.padding_overhead_cycles / max(self.merged_cycles, 1)


def _plan_schedule_cycles(plan) -> int:
    """Scheduled cycles of one emitted plan: Algorithm-1 logic cycles + final
    read + SBG input initialization (``input_init_cycles`` reads only
    ``plan.pis``, so it prices plans directly).  Falls back to the pass count
    for hand-built plans that carry no schedule.
    """
    init = input_init_cycles(plan)
    if plan.schedule is None:
        return plan.n_passes + 1 + init
    return plan.schedule.total_cycles(init)


def evaluate_bank_plan(bank, cfg: StochIMCConfig,
                       q_lanes: int | None = None,
                       active=None) -> BankPlanCost:
    """Map merged-plan pass counts onto the [n, m] bank model (Fig. 8).

    ``bank`` is a ``core.plan.BankPlan``.  One fused pass = one bank cycle:
    the same gate type fires across every occupied column of every subarray
    simultaneously, so same-type gates of a level — *across member circuits*,
    which occupy disjoint columns — share the pass.  Bitstream bits occupy
    ``q_lanes`` rows per subarray (default: all rows) and spread over the
    bank's n*m subarrays; longer streams pipeline (``pipeline_factor``
    sequential bank passes, the paper's evaluation mode).

    Merged vs looped: a per-member dispatch loop pays every member's own pass
    count (types can't share passes across dispatches) *and* one hierarchical
    accumulation (n + m steps) per dispatch, while the merged plan pays its
    cross-member type-batched passes once and accumulates all members' output
    columns in one n + m hierarchy — this is the memory-level-parallelism gap
    the bank merging closes, and what Table-3 accounting reflects when N
    instances are served per bank.

    ``active`` (per-member bools; default: every non-identity member) marks
    the slots actually bound to requests in a padded bank template.  The
    looped baseline loops over *active* members only, and the padding
    overhead fields report the extra passes the padded bank executes beyond
    an exact-fit merge of the active members — the honest cost of keeping
    the template/jit caches warm.
    """
    from .plan import merged_pass_count

    q = q_lanes if q_lanes is not None else cfg.subarray_rows
    lanes_per_pass = q * cfg.subarrays_per_bank * cfg.n_banks
    pipeline = max(1, math.ceil(cfg.bitstream_length / lanes_per_pass))
    acc = cfg.accumulation_steps()
    if active is None:
        active_plans = [m for m in bank.members if not m.is_identity]
    else:
        if len(active) != bank.n_members:
            raise ValueError(f"active: got {len(active)} for "
                             f"{bank.n_members} members")
        active_plans = [m for m, a in zip(bank.members, active) if a]
    active_passes = merged_pass_count(active_plans)
    merged = bank.n_passes * pipeline + acc
    looped = sum(m.n_passes for m in active_plans) * pipeline \
        + acc * len(active_plans)
    pad_passes = bank.n_passes - active_passes
    # Schedule-based pricing: every plan the pipeline emits carries its
    # Algorithm-1 Schedule, so the bank can be priced on the actual row/lane
    # schedule (init cycles + intra-subarray parallelism) instead of raw
    # pass counts.
    merged_sched = sum(_plan_schedule_cycles(g)
                       for g in (bank.comb, bank.seq) if g is not None)
    looped_sched = sum(_plan_schedule_cycles(m) for m in active_plans)
    group_plans = [g for g in (bank.comb, bank.seq) if g is not None]
    max_live = max((g.max_live for g in group_plans), default=0)
    naive_live = max((g.naive_live for g in group_plans), default=0)
    return BankPlanCost(
        n_members=bank.n_members,
        merged_passes=bank.n_passes,
        looped_passes=sum(m.n_passes for m in active_plans),
        pipeline_factor=pipeline,
        accumulation_cycles=acc,
        merged_cycles=merged,
        looped_cycles=looped,
        active_members=len(active_plans),
        active_passes=active_passes,
        padding_overhead_passes=pad_passes,
        padding_overhead_cycles=pad_passes * pipeline,
        schedule_cycles=merged_sched * pipeline + acc,
        looped_schedule_cycles=looped_sched * pipeline
        + acc * len(active_plans),
        max_live=max_live,
        naive_live=naive_live,
        live_occupancy_frac=max_live / max(cfg.subarray_rows, 1),
    )


@dataclasses.dataclass(frozen=True)
class MultiBankCost:
    """Aggregate cycle model for several banks executing concurrently.

    Models the multi-bank serving regime (serve/sc_engine.BankServer with
    several devices): each bank runs one merged plan independently, so the
    makespan is the *slowest* bank while a single-bank server pays the *sum*.
    ``bank_speedup`` is that serial/parallel ratio — the bank-level
    parallelism axis of the paper's 135.7X claim, orthogonal to the
    within-bank SIMD speedup each ``BankPlanCost`` already reports.
    """

    per_bank: "tuple[BankPlanCost, ...]"
    parallel_cycles: int         # makespan: max over banks
    serial_cycles: int           # single-bank server: sum over banks
    total_members: int
    total_active: int
    #: Schedule-priced analogues (see BankPlanCost.schedule_cycles).
    parallel_schedule_cycles: int = 0
    serial_schedule_cycles: int = 0

    @property
    def n_banks(self) -> int:
        return len(self.per_bank)

    @property
    def bank_speedup(self) -> float:
        """Serial-over-parallel ratio across banks (<= n_banks; equality iff
        perfectly balanced)."""
        return self.serial_cycles / max(self.parallel_cycles, 1)

    @property
    def balance(self) -> float:
        """Load balance in (0, 1]: mean bank cycles over makespan."""
        if not self.per_bank:
            return 1.0
        return (self.serial_cycles / len(self.per_bank)) \
            / max(self.parallel_cycles, 1)

    def requests_per_kilocycle(self) -> float:
        """Aggregate steady-state throughput: bound members retired per 1000
        bank cycles of makespan."""
        return 1000.0 * self.total_active / max(self.parallel_cycles, 1)


def evaluate_multibank(banks, cfg: StochIMCConfig,
                       actives=None,
                       q_lanes: int | None = None) -> MultiBankCost:
    """Aggregate ``evaluate_bank_plan`` over concurrently-executing banks.

    ``banks`` is a sequence of ``core.plan.BankPlan`` (one per physical bank
    / device); ``actives`` optionally gives each bank's bound-slot mask, as
    in ``evaluate_bank_plan``.  The model assumes the banks are independent
    (disjoint subarrays, no shared accumulator), which is exactly the
    BankServer placement contract: one batch per device at a time.
    """
    banks = list(banks)
    if not banks:
        raise ValueError("evaluate_multibank: need at least one bank")
    if actives is None:
        actives = [None] * len(banks)
    if len(actives) != len(banks):
        raise ValueError(f"actives: got {len(actives)} for "
                         f"{len(banks)} banks")
    costs = tuple(evaluate_bank_plan(b, cfg, q_lanes=q_lanes, active=a)
                  for b, a in zip(banks, actives))
    return MultiBankCost(
        per_bank=costs,
        parallel_cycles=max(c.merged_cycles for c in costs),
        serial_cycles=sum(c.merged_cycles for c in costs),
        total_members=sum(c.n_members for c in costs),
        total_active=sum(c.active_members for c in costs),
        parallel_schedule_cycles=max(c.schedule_cycles for c in costs),
        serial_schedule_cycles=sum(c.schedule_cycles for c in costs),
    )


def lifetime_improvement(a: AppCost, baseline: AppCost) -> float:
    """Eq. (11) ratio: (E_max * C / B) relative to baseline, with C = utilized
    cells and B = write traffic (write accesses dominate endurance)."""
    return (a.cells_used / a.cell_writes) / (baseline.cells_used / baseline.cell_writes)
