"""The staged compile pipeline: Netlist / member plans -> ExecutionPlan.

One explicit ``PassPipeline`` replaces the three divergent compile paths that
used to live inline in ``plan.py``:

    normalize -> elide_cse -> fuse -> level -> schedule -> liveness
              -> stream_table -> emit

* ``lower_netlist`` runs the full pipeline on a single ``Netlist`` (the
  ``compile_plan`` path; ``fuse=False`` turns the structural stages into
  no-ops so per-gate fault injection observes every intermediate stream).
* ``merge_plans`` merges already-lowered member plans level-by-level
  (cross-member type batching) and enters the SAME pipeline at the
  ``schedule`` stage — merged-bank and padded-template compilation share the
  single tail (schedule -> liveness -> stream_table -> emit) with the
  single-netlist path, so every ``ExecutionPlan``, merged or not, carries an
  Algorithm-1 ``Schedule``, a liveness scratch assignment, and a stream
  table built by the same stages.

Stages communicate through a mutable ``Lowering`` context; each stage is a
pure function of it, so alternative pipelines (e.g. a no-schedule variant for
tooling) are just different stage tuples.  Caching/interning stays in the
``repro.core.plan`` facade — the pipeline itself is stateless apart from the
process-wide ``serial`` stamp.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

from .. import obs
from ..gates import Netlist
from .ir import (FUSED_MUX, FUSED_XOR, BankPlan, CompiledOp, ExecutionPlan,
                 build_stream_table, member_prefix)
from .stages import (_WGate, _WOp, _absorb_nots, _elide_and_cse, _find_mux_fusions,
                     _find_xor_fusions, _fold_ands, assign_liveness, level_ops,
                     schedule_passes)

# Monotone compile stamp shared by plans and banks (ExecutionPlan.serial /
# BankPlan.serial).  Deliberately NOT reset by plan.clear_cache(): serial
# order anchors bank-template canonical member order across cache epochs.
_SERIAL = itertools.count()


def next_serial() -> int:
    """Next process-wide compile stamp (plans, banks)."""
    return next(_SERIAL)


@dataclasses.dataclass
class Lowering:
    """Mutable compile context threaded through the pipeline stages.

    The front half (``source``, ``fuse``) is set by the entry point; each
    stage fills in its output fields; ``emit`` assembles the final
    ``ExecutionPlan`` into ``plan``.  The merge front-end pre-fills the
    leveled fields and runs only the shared tail stages.
    """

    name: str
    pis: tuple
    outputs: tuple[str, ...]
    state_pis: tuple[str, ...] = ()
    state_drivers: tuple[str, ...] = ()
    state_inits: tuple[float, ...] = ()
    fuse: bool = True
    n_gates: int = 0
    source: Netlist | None = None           # netlist front-end only
    # -- stage outputs ------------------------------------------------------
    protected: set = dataclasses.field(default_factory=set)
    work_gates: list = dataclasses.field(default_factory=list)
    alias: dict = dataclasses.field(default_factory=dict)
    ops: list = dataclasses.field(default_factory=list)
    levels: tuple = ()
    counters: dict = dataclasses.field(default_factory=lambda: {
        "buff_elided": 0, "cse_elided": 0, "mux_fused": 0,
        "xor_fused": 0, "and_fused": 0, "not_absorbed": 0})
    stream_table: Any = None
    schedule: Any = None
    max_live: int = 0
    pi_slots: tuple = ()
    plan: ExecutionPlan | None = None


# --------------------------------- stages ------------------------------------------

def stage_normalize(ctx: Lowering) -> None:
    """Validate the source netlist and snapshot the observable-node set."""
    net = ctx.source
    net.validate()
    ctx.n_gates = len(net.gates)
    ctx.protected = set(net.outputs) | {drv for drv, _
                                        in net.state_bindings.values()}
    if not ctx.fuse:
        # Per-gate fault injection must observe every intermediate stream:
        # no elision, no dedup, no fusion (mirrors the interpreter exactly).
        ctx.work_gates = [_WGate(g.gid, g.gtype, g.inputs, g.output)
                          for g in net.gates]


def stage_elide_cse(ctx: Lowering) -> None:
    """BUFF elision + structural CSE (rewrites the graph fusion will see)."""
    if not ctx.fuse:
        return
    gates, alias, n_buff, n_cse = _elide_and_cse(ctx.source.gates)
    # Only observable elided nodes (outputs / state drivers) need re-exposing
    # at execution time — every other use was rewritten to the survivor.
    # Restricting the recorded aliases to those keeps the next stage sound: a
    # dangling alias to a node fusion then absorbs would crash the re-expose
    # loop.
    alias = {s: d for s, d in alias.items() if s in ctx.protected}
    # An elided observable node aliases its survivor — which makes the
    # SURVIVOR observable too: resolve protection through the aliases so
    # pattern fusion cannot absorb a node some alias must re-expose.
    ctx.protected |= set(alias.values())
    ctx.work_gates = gates
    ctx.alias = alias
    ctx.counters["buff_elided"] = n_buff
    ctx.counters["cse_elided"] = n_cse


def stage_fuse(ctx: Lowering) -> None:
    """Pattern fusion (MUX/XOR) + NOT-directed cleanups (AND fold, absorb)."""
    if ctx.fuse:
        mux_roots, dead = _find_mux_fusions(ctx.work_gates, ctx.protected)
        xor_roots = _find_xor_fusions(ctx.work_gates, ctx.protected, dead)
    else:
        mux_roots, dead, xor_roots = {}, set(), {}
    # Materialize the post-pattern-fusion op list, then run the NOT-directed
    # cleanups on it.  Both run after the 4-gate matchers so the NOT-bearing
    # MUX/XOR forms are recognized first.
    ops: list[_WOp] = []
    for g in ctx.work_gates:
        if g.gid in dead:
            continue
        if g.gid in mux_roots:
            op, ins = FUSED_MUX, mux_roots[g.gid]
        elif g.gid in xor_roots:
            op, ins = FUSED_XOR, xor_roots[g.gid]
        else:
            op, ins = g.gtype, g.inputs
        ops.append(_WOp(g.gid, op, tuple(ins), (False,) * len(ins), g.output))
    if ctx.fuse:
        n_and = _fold_ands(ops, ctx.protected)
        n_not = _absorb_nots(ops, ctx.protected)
    else:
        n_and = n_not = 0
    ctx.ops = ops
    ctx.counters["mux_fused"] = len(mux_roots)
    ctx.counters["xor_fused"] = len(xor_roots)
    ctx.counters["and_fused"] = n_and
    ctx.counters["not_absorbed"] = n_not


def stage_level(ctx: Lowering) -> None:
    """Longest-path leveling with per-level (op, neg) type batching."""
    ctx.levels = level_ops(ctx.ops, (p.name for p in ctx.pis))


def stage_schedule(ctx: Lowering) -> None:
    """Algorithm 1 over the leveled passes (see ``stages.schedule_passes``)."""
    ctx.schedule = schedule_passes(ctx.name, ctx.pis, ctx.levels)


def stage_liveness(ctx: Lowering) -> None:
    """Last-use analysis + scratch-slot assignment over the leveled passes.

    Runs after ``schedule`` (the pass order is final) and before
    ``stream_table``, and — like both — on every compile path: single
    netlists, merged BankPlans, and padded templates all enter at or before
    this stage, so every ``ExecutionPlan`` carries ``max_live``/``pi_slots``
    and per-op ``slots``/``free_after``.  Observable nodes are protected
    through the alias map: an elided output's survivor must stay live for the
    executor's re-expose step.
    """
    observable = set(ctx.outputs) | set(ctx.state_drivers)
    protected = {ctx.alias.get(nm, nm) for nm in observable}
    ctx.levels, ctx.pi_slots, ctx.max_live = assign_liveness(
        ctx.levels, (p.name for p in ctx.pis), protected)


def stage_stream_table(ctx: Lowering) -> None:
    """Lay out the batched-SNG stream table over the plan's PIs."""
    ctx.stream_table = build_stream_table(ctx.pis)


def stage_emit(ctx: Lowering) -> None:
    """Assemble the frozen ExecutionPlan from the staged context."""
    c = ctx.counters
    ctx.plan = ExecutionPlan(
        name=ctx.name,
        pis=tuple(ctx.pis),
        n_gates=ctx.n_gates,
        levels=ctx.levels,
        outputs=tuple(ctx.outputs),
        state_pis=ctx.state_pis,
        state_drivers=ctx.state_drivers,
        state_inits=ctx.state_inits,
        fused=ctx.fuse,
        n_fused_mux=c["mux_fused"],
        stream_table=ctx.stream_table,
        aliases=tuple(sorted(ctx.alias.items())),
        n_fused_xor=c["xor_fused"],
        n_buff_elided=c["buff_elided"],
        n_cse_elided=c["cse_elided"],
        n_fused_and=c["and_fused"],
        n_not_absorbed=c["not_absorbed"],
        serial=next_serial(),
        schedule=ctx.schedule,
        max_live=ctx.max_live,
        pi_slots=ctx.pi_slots,
    )


@dataclasses.dataclass(frozen=True)
class PassPipeline:
    """An ordered tuple of named compile stages over a ``Lowering`` context.

    ``run(ctx)`` applies every stage in order; ``run(ctx, start=...)`` enters
    at a named stage (the merge front-end joins at ``"schedule"``).  Stage
    names are part of the public shape: tooling and tests address the
    pipeline by them.
    """

    stages: tuple[tuple[str, Callable[[Lowering], None]], ...]

    @property
    def stage_names(self) -> tuple[str, ...]:
        return tuple(name for name, _ in self.stages)

    def run(self, ctx: Lowering, start: str | None = None) -> ExecutionPlan:
        tr = obs.current_trace()
        started = start is None
        for name, fn in self.stages:
            started = started or name == start
            if started:
                if tr is None:
                    fn(ctx)
                else:
                    with tr.span(f"compile.{name}", plan=ctx.name):
                        fn(ctx)
        if not started:
            raise ValueError(f"unknown pipeline stage {start!r}; "
                             f"have {self.stage_names}")
        return ctx.plan


#: The one pipeline every compile path flows through.
DEFAULT_PIPELINE = PassPipeline((
    ("normalize", stage_normalize),
    ("elide_cse", stage_elide_cse),
    ("fuse", stage_fuse),
    ("level", stage_level),
    ("schedule", stage_schedule),
    ("liveness", stage_liveness),
    ("stream_table", stage_stream_table),
    ("emit", stage_emit),
))


# ------------------------------- entry points --------------------------------------

def lower_netlist(net: Netlist, fuse_mux: bool = True,
                  pipeline: PassPipeline | None = None) -> ExecutionPlan:
    """Lower one netlist through the full pipeline (uncached).

    The caching/interning front (per-instance memo + structure-keyed LRU)
    lives in the ``repro.core.plan`` facade; this is the pure compile.
    """
    state_items = sorted(net.state_bindings.items())
    ctx = Lowering(
        name=net.name,
        pis=tuple(net.pis),
        outputs=tuple(net.outputs),
        state_pis=tuple(s for s, _ in state_items),
        state_drivers=tuple(d for _, (d, _) in state_items),
        state_inits=tuple(i for _, (_, i) in state_items),
        fuse=fuse_mux,
        source=net,
    )
    return (pipeline or DEFAULT_PIPELINE).run(ctx)


def merge_plans(plans: "list[ExecutionPlan]", indices: "list[int]",
                name: str,
                pipeline: PassPipeline | None = None) -> ExecutionPlan:
    """Merge same-kind plans into one cross-member type-batched plan.

    ``indices`` are the members' caller-order positions — they become the node
    namespace prefixes, so the executor can scatter outputs back per member.
    Members are independent graphs, so each gate keeps its per-member level;
    merging level ``L`` across members and type-batching within it is a valid
    re-leveling of the union graph.  Gate ids are offset by the running gate
    count so they index a flat per-merge-order fault-key array.  Identity
    (padding) members contribute no nodes and are exempt from the kind check,
    so a padded bank template can carry them in either group.

    The merged levels re-enter the shared pipeline at the ``schedule`` stage:
    merged plans get their Algorithm-1 schedule and stream table from the
    same stages as single-netlist plans.  (The structural stages must NOT
    re-run here — members were optimized per-netlist, and cross-member
    rewrites would break the per-member key discipline's bit-identity.)
    """
    if len({p.is_sequential for p in plans if not p.is_identity}) > 1:
        raise ValueError("merge_plans: cannot mix sequential and "
                         "combinational members in one merged plan")
    prefixes = [member_prefix(i) for i in indices]
    offsets = []
    off = 0
    for p in plans:
        offsets.append(off)
        off += p.n_gates

    n_levels = max(len(p.levels) for p in plans)
    levels = []
    for lvl in range(n_levels):
        by_op: dict[tuple, list[tuple]] = {}
        for p, pre, goff in zip(plans, prefixes, offsets):
            if lvl >= len(p.levels):
                continue
            for cop in p.levels[lvl]:
                by_op.setdefault((cop.op, cop.neg), []).append((cop, pre, goff))
        ops = []
        for (op, neg), entries in by_op.items():
            arity = len(entries[0][0].inputs)
            ops.append(CompiledOp(
                op=op,
                gids=tuple(goff + g for cop, _, goff in entries
                           for g in cop.gids),
                inputs=tuple(tuple(pre + n for cop, pre, _ in entries
                                   for n in cop.inputs[j])
                             for j in range(arity)),
                outputs=tuple(pre + o for cop, pre, _ in entries
                              for o in cop.outputs),
                neg=neg,
            ))
        levels.append(tuple(ops))

    pis = tuple(dataclasses.replace(
        pi, name=pre + pi.name,
        corr_group=(pre + pi.corr_group) if pi.corr_group else None)
        for p, pre in zip(plans, prefixes) for pi in p.pis)
    # NOTE: the merged stream table is laid out over the *merged* PI list, so
    # its lanes differ from the members' own tables.  Bank execution generates
    # streams from each member's table with that member's key (preserving
    # merged == looped bit-identity); the merged table exists for plans
    # executed standalone.
    ctx = Lowering(
        name=name,
        pis=pis,
        outputs=tuple(pre + o for p, pre in zip(plans, prefixes)
                      for o in p.outputs),
        state_pis=tuple(pre + s for p, pre in zip(plans, prefixes)
                        for s in p.state_pis),
        state_drivers=tuple(pre + d for p, pre in zip(plans, prefixes)
                            for d in p.state_drivers),
        state_inits=tuple(i for p in plans for i in p.state_inits),
        # Identity padding members are vacuously "fused"; only real members
        # decide whether the merged plan admits per-gate fault injection.
        fuse=any(p.fused for p in plans if not p.is_identity),
        n_gates=off,
        levels=tuple(levels),
        counters={
            "buff_elided": sum(p.n_buff_elided for p in plans),
            "cse_elided": sum(p.n_cse_elided for p in plans),
            "mux_fused": sum(p.n_fused_mux for p in plans),
            "xor_fused": sum(p.n_fused_xor for p in plans),
            "and_fused": sum(p.n_fused_and for p in plans),
            "not_absorbed": sum(p.n_not_absorbed for p in plans),
        },
    )
    ctx.alias = {pre + a: pre + b for p, pre in zip(plans, prefixes)
                 for a, b in p.aliases}
    return (pipeline or DEFAULT_PIPELINE).run(ctx, start="schedule")


def build_bank(members: "tuple[ExecutionPlan, ...]",
               name: str | None = None) -> BankPlan:
    """Merge a member-plan tuple into a BankPlan (uncached).

    Splits members into the combinational and sequential merge groups and
    runs each through ``merge_plans`` (i.e. the shared pipeline tail).  The
    cache front lives in the ``repro.core.plan`` facade.
    """
    comb_idx = tuple(i for i, m in enumerate(members) if not m.is_sequential)
    seq_idx = tuple(i for i, m in enumerate(members) if m.is_sequential)
    bank_name = name or f"bank{len(members)}"
    comb = merge_plans([members[i] for i in comb_idx], list(comb_idx),
                       f"{bank_name}/comb") if comb_idx else None
    seq = merge_plans([members[i] for i in seq_idx], list(seq_idx),
                      f"{bank_name}/seq") if seq_idx else None
    return BankPlan(name=bank_name, members=members, comb=comb, seq=seq,
                    comb_members=comb_idx, seq_members=seq_idx,
                    serial=next_serial())
