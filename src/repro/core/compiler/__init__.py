"""Staged compiler pipeline: Netlist -> ExecutionPlan / BankPlan.

Layout:

  * ``ir.py``       — typed lowering IR (CompiledOp, StreamTable,
                      ExecutionPlan, BankPlan);
  * ``stages.py``   — individual transformation stages (structural passes,
                      leveling, the Algorithm-1 schedule stage);
  * ``pipeline.py`` — the ``PassPipeline`` and its entry points
                      (``lower_netlist``, ``merge_plans``, ``build_bank``).

External code imports through the ``repro.core.plan`` facade (which adds the
caching layer); importing this package's internals from outside ``repro.core``
is banned by ruff TID251.
"""
from .ir import (FUSED_MUX, FUSED_XOR, IDENTITY_NAME, BankPlan, CompiledOp,
                 ExecutionPlan, StreamTable, build_stream_table, member_prefix)
from .pipeline import (DEFAULT_PIPELINE, Lowering, PassPipeline, build_bank,
                       lower_netlist, merge_plans, next_serial)

__all__ = [
    "FUSED_MUX", "FUSED_XOR", "IDENTITY_NAME", "BankPlan", "CompiledOp",
    "ExecutionPlan", "StreamTable", "build_stream_table", "member_prefix",
    "DEFAULT_PIPELINE", "Lowering", "PassPipeline", "build_bank",
    "lower_netlist", "merge_plans", "next_serial",
]
