"""Individual compiler stages: structural passes, leveling, scheduling.

Every function here is one transformation step consumed by the staged
``PassPipeline`` (``compiler/pipeline.py``).  The structural cleanups are all
boolean identities, so optimized plans stay bit-identical to the reference
interpreter; they are disabled together (``fuse=False``) when per-gate fault
injection must observe every intermediate stream:

  * **BUFF elision** — copy gates become node aliases (zero passes);
  * **structural CSE** — same gate type over the same (resolved, order-
    canonicalized for commutative types) inputs computes the same stream, so
    duplicates alias the first occurrence;
  * **pattern fusion** — the 4-gate stochastic scaled addition
    ``NAND(NAND(a,s), NAND(b, NOT(s)))`` fuses to one MUX pass
    ``(a & s) | (b & ~s)``, and the 4-NAND XOR form
    ``NAND(NAND(a,n1), NAND(b,n1))`` with ``n1 = NAND(a,b)`` fuses to one
    XOR pass (the |a-b| subtractor of Fig. 5(c));
  * **NOT-directed cleanups** — ``NOT(NAND(a,b))`` folds to one fused AND
    pass, and lone single-use NOTs absorb into their consuming pass via the
    per-input ``neg`` mask.

The **schedule stage** runs the paper's Algorithm 1 (``core/scheduler.py``)
over the leveled passes: each fused pass is one SIMD gate spanning all rows
(one V_SL drive pattern fires the same gate type across every column), so the
resulting ``Schedule`` prices the plan's in-memory cycles — intra-subarray
parallelism, preset overlap, and (via ``scheduler.input_init_cycles``) the
SBG input-initialization cycles — instead of raw pass counts.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

from ..gates import ALL_ROWS, Netlist
from ..scheduler import Schedule, schedule
from .ir import _COMMUTATIVE, CompiledOp

# ------------------------- pre-leveling optimization -------------------------------


@dataclasses.dataclass(frozen=True)
class _WGate:
    """Working gate record during compilation (inputs already alias-resolved)."""

    gid: int
    gtype: str
    inputs: tuple[str, ...]
    output: str


def _elide_and_cse(gates):
    """BUFF elision + structural CSE over a topological gate list.

    Returns ``(kept, alias, n_buff, n_cse)``.  BUFF gates become aliases to
    their (resolved) input; a gate whose (type, resolved inputs) — input
    order canonicalized for commutative types — matches an earlier survivor
    aliases that survivor's output.  Both are exact stream identities: the
    interpreter computes the same deterministic function at both sites, so
    aliasing is bit-identical, not approximate.  Gates are visited in
    construction (topological) order, so alias chains resolve in one pass.
    """
    alias: dict[str, str] = {}
    seen: dict[tuple, str] = {}
    kept: list[_WGate] = []
    n_buff = n_cse = 0
    for g in gates:
        ins = tuple(alias.get(i, i) for i in g.inputs)
        if g.gtype == "BUFF":
            alias[g.output] = ins[0]
            n_buff += 1
            continue
        key = (g.gtype, tuple(sorted(ins)) if g.gtype in _COMMUTATIVE else ins)
        prev = seen.get(key)
        if prev is not None:
            alias[g.output] = prev
            n_cse += 1
            continue
        seen[key] = g.output
        kept.append(_WGate(g.gid, g.gtype, ins, g.output))
    return kept, alias, n_buff, n_cse


def _count_uses(gates) -> dict[str, int]:
    uses: dict[str, int] = defaultdict(int)
    for g in gates:
        for i in g.inputs:
            uses[i] += 1
    return uses


def _find_mux_fusions(
        gates, protected: set[str],
) -> tuple[dict[int, tuple[str, str, str]], set[int]]:
    """Detect fusable 4-gate MUX groups over a working gate list.

    Returns ``(roots, dead)``: ``roots`` maps the root NAND's gid to its
    ``(a, b, s)`` operand nodes; ``dead`` holds gids of the three absorbed
    feeder gates.  A feeder is absorbed only when its output has exactly one
    use and is neither a primary output nor a state driver — otherwise the
    intermediate stream is observable and must stay materialized.
    """
    driver = {g.output: g for g in gates}
    uses = _count_uses(gates)

    def absorbable(node: str) -> bool:
        return uses[node] == 1 and node not in protected

    roots: dict[int, tuple[str, str, str]] = {}
    dead: set[int] = set()
    for g in gates:
        if g.gtype != "NAND" or g.gid in dead:
            continue
        g1 = driver.get(g.inputs[0])
        g2 = driver.get(g.inputs[1])
        if g1 is None or g2 is None or g1.gid == g2.gid:
            continue
        if g1.gtype != "NAND" or g2.gtype != "NAND":
            continue
        if {g1.gid, g2.gid} & dead:
            continue
        found = None
        for x, y in ((g1, g2), (g2, g1)):
            # y = NAND(b, sb) with sb = NOT(s), x = NAND(a, s).
            for bi in (0, 1):
                sb_gate = driver.get(y.inputs[1 - bi])
                if sb_gate is None or sb_gate.gtype != "NOT" or sb_gate.gid in dead:
                    continue
                s = sb_gate.inputs[0]
                if s not in x.inputs:
                    continue
                a = x.inputs[1] if x.inputs[0] == s else x.inputs[0]
                b = y.inputs[bi]
                if (absorbable(x.output) and absorbable(y.output)
                        and absorbable(sb_gate.output)):
                    found = (a, b, s, x.gid, y.gid, sb_gate.gid)
                    break
            if found:
                break
        if found:
            a, b, s, xg, yg, sg = found
            roots[g.gid] = (a, b, s)
            dead.update((xg, yg, sg))
    return roots, dead


def _find_xor_fusions(gates, protected: set[str],
                      dead: set[int]) -> dict[int, tuple[str, str]]:
    """Detect the 4-NAND XOR form and fuse it to one XOR pass.

    Pattern (Fig. 5(c)'s |a-b| subtractor): ``n1 = NAND(a, b)``;
    ``root = NAND(NAND(a, n1), NAND(b, n1))`` computes ``a ^ b``.  The three
    feeder NANDs are absorbed only when they are single-purpose — ``n1`` used
    exactly by the two mid gates, each mid gate used only by the root, and
    none of them observable (primary output / state driver).  Extends
    ``dead`` in place; returns root gid -> (a, b).
    """
    driver = {g.output: g for g in gates}
    uses = _count_uses(gates)
    roots: dict[int, tuple[str, str]] = {}
    for g in gates:
        if g.gtype != "NAND" or g.gid in dead:
            continue
        x = driver.get(g.inputs[0])
        y = driver.get(g.inputs[1])
        if x is None or y is None or x.gid == y.gid:
            continue
        if x.gtype != "NAND" or y.gtype != "NAND":
            continue
        if {x.gid, y.gid} & dead:
            continue
        found = None
        for c in x.inputs:                       # shared mid node candidate
            if c not in y.inputs:
                continue
            n1 = driver.get(c)
            if n1 is None or n1.gtype != "NAND" or n1.gid in dead:
                continue
            a = x.inputs[1] if x.inputs[0] == c else x.inputs[0]
            b = y.inputs[1] if y.inputs[0] == c else y.inputs[0]
            if a == b or set(n1.inputs) != {a, b}:
                continue
            if (uses[c] == 2 and uses[x.output] == 1 and uses[y.output] == 1
                    and not {c, x.output, y.output} & protected):
                found = (a, b, x.gid, y.gid, n1.gid)
                break
        if found:
            a, b, xg, yg, ng = found
            roots[g.gid] = (a, b)
            dead.update((xg, yg, ng))
    return roots


@dataclasses.dataclass(frozen=True)
class _WOp:
    """Post-pattern-fusion working op (gate type or MUX3/XOR, + neg mask)."""

    gid: int
    op: str
    inputs: tuple[str, ...]
    neg: tuple[bool, ...]
    output: str


def _fold_ands(ops: "list[_WOp]", protected: set[str]) -> int:
    """Fold ``NOT(NAND(a, b))`` pairs into one fused AND pass.

    The 2T-1MTJ method has no AND primitive — stochastic multiplication is a
    NAND feeding a NOT (two memory cycles) — but the plan level does: the
    boolean identity ``NOT(NAND(a, b)) == AND(a, b)`` collapses the pair to
    one pass whenever the intermediate NAND output is single-use and
    unobservable.  The surviving op keeps the NOT's gid and output node (and
    the NAND's neg mask, vacuously all-False at this stage).  Mutates ``ops``
    in place; returns the number of folded pairs.
    """
    driver = {w.output: i for i, w in enumerate(ops)}
    uses = _count_uses(ops)
    dead: set[int] = set()
    n = 0
    for i, w in enumerate(ops):
        if w.op != "NOT" or w.neg[0]:
            continue
        j = driver.get(w.inputs[0])
        if j is None or j in dead:
            continue
        s = ops[j]
        if s.op != "NAND" or uses[s.output] != 1 or s.output in protected:
            continue
        ops[i] = _WOp(w.gid, "AND", s.inputs, s.neg, w.output)
        dead.add(j)
        n += 1
    if dead:
        ops[:] = [w for i, w in enumerate(ops) if i not in dead]
    return n


def _absorb_nots(ops: "list[_WOp]", protected: set[str]) -> int:
    """Fuse lone NOT gates into their consuming pass via the neg mask.

    A NOT whose output has exactly one use and is unobservable disappears:
    its consumer reads the NOT's *input* with the complement folded into the
    pass (``CompiledOp.neg``) — an exact stream identity, one fewer pass.
    Ops are visited in topological order, so NOT chains collapse step by step
    (``NOT(NOT(x))`` absorbs to a plain ``x`` read).  Mutates ``ops`` in
    place; returns the number of absorbed NOTs.
    """
    uses = _count_uses(ops)
    consumers: dict[str, list[tuple[int, int]]] = defaultdict(list)
    for i, w in enumerate(ops):
        for p, nm in enumerate(w.inputs):
            consumers[nm].append((i, p))
    dead: set[int] = set()
    n = 0
    for i, w in enumerate(ops):
        if w.op != "NOT" or i in dead:
            continue
        if w.output in protected or uses[w.output] != 1:
            continue
        (ci, pos), = consumers[w.output]
        if ci in dead:
            continue
        c = ops[ci]
        src = w.inputs[0]
        ins = list(c.inputs)
        ins[pos] = src
        neg = list(c.neg)
        # NOT with its own neg set is a double negation: absorbing it passes
        # the source through uncomplemented.
        neg[pos] = neg[pos] != (not w.neg[0])
        ops[ci] = _WOp(c.gid, c.op, tuple(ins), tuple(neg), c.output)
        consumers[src].append((ci, pos))
        uses[src] += 1
        dead.add(i)
        n += 1
    if dead:
        ops[:] = [w for i, w in enumerate(ops) if i not in dead]
    return n


# --------------------------------- leveling ----------------------------------------

def level_ops(ops: "list[_WOp]", pi_names) -> tuple:
    """Longest-path leveling over the optimized op graph (PIs at level 0).

    Ops batch within a level by (op, neg) — a complemented-input variant is
    its own pass.  Returns the ``ExecutionPlan.levels`` tuple.
    """
    level: dict[str, int] = {name: 0 for name in pi_names}
    by_level: dict[int, dict[tuple, list[tuple[int, tuple[str, ...], str]]]] = \
        defaultdict(lambda: defaultdict(list))
    for w in ops:
        lvl = 1 + max(level[i] for i in w.inputs)
        level[w.output] = lvl
        neg = w.neg if any(w.neg) else ()
        by_level[lvl][(w.op, neg)].append((w.gid, w.inputs, w.output))

    levels = []
    for lvl in sorted(by_level):
        lvl_ops = []
        for (op, neg), entries in by_level[lvl].items():
            arity = len(entries[0][1])
            lvl_ops.append(CompiledOp(
                op=op,
                gids=tuple(e[0] for e in entries),
                inputs=tuple(tuple(e[1][j] for e in entries) for j in range(arity)),
                outputs=tuple(e[2] for e in entries),
                neg=neg,
            ))
        levels.append(tuple(lvl_ops))
    return tuple(levels)


# ------------------------------- liveness stage ------------------------------------

def assign_liveness(levels, pi_names, protected):
    """Last-use analysis + register-allocation-style scratch assignment.

    Walks the plan's passes in execution order and computes, for every node
    stream (PI or pass output), the pass after which it is dead.  Dead nodes
    release their scratch slot back to a free pool; live ones hold it — so
    the pool's high-water mark (``max_live``) is the peak number of
    simultaneously-resident streams, the VMEM scratch size the megakernel
    allocates and the subarray-occupancy metric ``arch`` prices (vs
    ``naive_live`` for a keep-everything executor).

    Allocation is conservative within a pass: slots freed by pass ``i`` are
    reusable from pass ``i+1``, never by pass ``i``'s own outputs — a batched
    pass computes its gates one after another, so reusing a dying input's
    slot for an earlier gate's output could clobber a later gate's operand.
    Freed slots are recycled lowest-numbered-first, keeping the assignment
    deterministic.

    ``protected`` nodes (plan outputs, state drivers — resolved through the
    alias map so an elided observable protects its survivor) are never freed.
    Returns ``(levels, pi_slots, max_live)`` where ``levels`` carries the
    per-op ``slots``/``free_after`` fields and ``pi_slots[i]`` is the slot of
    the i-th PI (``-1`` when no pass reads it and nothing re-exposes it).
    """
    pi_names = list(pi_names)
    passes = [cop for level in levels for cop in level]
    last_use: dict[str, int] = {}
    for i, cop in enumerate(passes):
        for row in cop.inputs:
            for nm in row:
                last_use[nm] = i

    slot_of: dict[str, int] = {}
    free_pool: list[int] = []
    n_slots = 0

    def alloc(name: str) -> int:
        nonlocal n_slots
        if free_pool:
            s = heapq.heappop(free_pool)
        else:
            s = n_slots
            n_slots += 1
        slot_of[name] = s
        return s

    live = {nm for nm in pi_names if nm in last_use or nm in protected}
    for nm in pi_names:
        if nm in live:
            alloc(nm)
    pi_slots = tuple(slot_of.get(nm, -1) for nm in pi_names)
    # PIs nothing reads (and nothing re-exposes) are dropped up front: the
    # executor deletes them after the first pass, the megakernel never loads
    # them.  They still count toward naive_live — a keep-everything executor
    # holds them for the whole plan.
    unused_pis = [nm for nm in pi_names
                  if nm not in last_use and nm not in protected]

    new_passes = []
    for i, cop in enumerate(passes):
        slots = tuple(alloc(o) for o in cop.outputs)
        dying = sorted(
            {nm for row in cop.inputs for nm in row
             if last_use[nm] == i and nm not in protected}
            | {o for o in cop.outputs
               if o not in last_use and o not in protected})
        if i == 0:
            dying = sorted(set(dying) | set(unused_pis))
        for nm in dying:
            if nm in slot_of:
                heapq.heappush(free_pool, slot_of.pop(nm))
        new_passes.append(dataclasses.replace(cop, slots=slots,
                                              free_after=tuple(dying)))

    out_levels, k = [], 0
    for level in levels:
        out_levels.append(tuple(new_passes[k:k + len(level)]))
        k += len(level)
    return tuple(out_levels), pi_slots, n_slots


# ------------------------------- schedule stage ------------------------------------

@dataclasses.dataclass(frozen=True)
class _PassGate:
    """Duck-typed gate for the pass-level scheduling view.

    Bypasses ``gates.Gate``'s arity checks: a fused pass reads an arbitrary
    number of source nodes and has the plan-level MUX3/XOR types.
    """

    gid: int
    gtype: str
    inputs: tuple[str, ...]
    output: str
    row: int = ALL_ROWS


class _PassGraph:
    """Netlist-shaped view of a plan's fused passes for Algorithm 1.

    One scheduling gate per ``CompiledOp``: a fused pass is one SIMD V_SL
    drive firing the same gate type in every occupied row/column of the
    subarray (the paper's intra-subarray parallelism, generalized bank-wide
    by cross-member type batching).  Dependencies are pass-to-pass: a pass
    consuming any node another pass produced waits for it; PI reads anchor to
    the plan's real ``PrimaryInput`` rows so ``input_init_cycles`` and the
    PI-mapping step see the true input layout.

    Implements exactly the ``scheduler.schedule`` surface: ``validate()``,
    ``inverse_topological_order()``, ``pis``, ``gates``, ``name``.
    """

    def __init__(self, name: str, pis, levels) -> None:
        self.name = name
        self.pis = tuple(pis)
        pi_names = {p.name for p in self.pis}
        producer: dict[str, str] = {}
        gates: list[_PassGate] = []
        for lvl in levels:
            for cop in lvl:
                token = f"pass{len(gates)}"
                deps: list[str] = []
                seen: set[str] = set()
                for col in cop.inputs:
                    for nm in col:
                        src = producer.get(nm, nm if nm in pi_names else None)
                        if src is not None and src not in seen:
                            seen.add(src)
                            deps.append(src)
                gates.append(_PassGate(len(gates), cop.op, tuple(deps), token))
                for nm in cop.outputs:
                    producer[nm] = token
        self.gates = gates

    def validate(self) -> None:
        pass

    def inverse_topological_order(self) -> dict[int, int]:
        """Distance to the farthest sink, per gate id (list-scheduling rank)."""
        consumers: dict[str, list[int]] = defaultdict(list)
        for g in self.gates:
            for i in g.inputs:
                consumers[i].append(g.gid)
        dist: dict[int, int] = {}
        for g in reversed(self.gates):            # reverse topological order
            outs = consumers.get(g.output, ())
            dist[g.gid] = 1 + max((dist[c] for c in outs), default=0)
        return dist


#: Effectively-unbounded subarray limits for plan/bank scheduling: capacity
#: judgement (does this bank fit an [n, m] configuration?) belongs to
#: ``arch``, not the compile pipeline — a merged bank may legitimately need
#: more columns than one physical subarray holds.
_SCHED_LIMIT = 1 << 30


def schedule_passes(name: str, pis, levels) -> Schedule:
    """Run Algorithm 1 over the leveled passes (the pipeline schedule stage).

    Every plan — single-netlist, merged-bank, padded-template member — gets a
    ``Schedule`` whose ``logic_cycles`` reflect the one-logic-op-per-row rule
    applied to its fused passes, with BUFF copies and placement accounted by
    the real scheduler.  ``n_lanes=1``: lane scaling (bitstream bits, batch
    instances) is applied by ``arch`` at pricing time.
    """
    return schedule(_PassGraph(name, pis, levels), n_lanes=1,
                    r_available=_SCHED_LIMIT, c_available=_SCHED_LIMIT)


# -------------------------------- signatures ---------------------------------------

def signature(net: Netlist) -> tuple:
    """Structural cache key of a netlist (PIs, gates, outputs, state)."""
    return (
        net.name,
        tuple(net.pis),
        tuple((g.gid, g.gtype, g.inputs, g.output) for g in net.gates),
        tuple(net.outputs),
        tuple(sorted((s, d, i) for s, (d, i) in net.state_bindings.items())),
    )
