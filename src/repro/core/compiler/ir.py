"""Typed lowering IR shared by every compiler stage.

The dataclasses here are the currency of the staged pipeline
(``compiler/pipeline.py``): a ``Netlist`` lowers through working-gate and
working-op forms (``compiler/stages.py``) into an ``ExecutionPlan`` — leveled,
type-batched fused passes plus the plan's stream table, Algorithm-1 schedule,
and optimization provenance counters.  ``BankPlan`` wraps N member plans
merged for bank-level execution.

Import surface: external code reaches these types through the
``repro.core.plan`` facade; only ``repro.core`` internals import this module
directly (enforced by the ruff TID251 ban).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from ..gates import PIKind, PrimaryInput

# Fused 3-input scaled addition: out = (a & s) | (b & ~s).  Not a 2T-1MTJ
# primitive — it exists only at the plan level (and as packed_logic's "mux").
FUSED_MUX = "MUX3"
# Fused 2-input XOR: out = a ^ b, recognized from its 4-NAND netlist form.
# Like MUX3, a plan-level op only (packed_logic's "xor").
FUSED_XOR = "XOR"

_OP_ARITY = {"MUX3": 3, "XOR": 2}

# Gate types whose input order is semantically irrelevant — their CSE key is
# order-canonicalized so NAND(a,b) and NAND(b,a) intern to one pass.
_COMMUTATIVE = {"AND", "NAND", "OR", "NOR", "XOR",
                "MAJ3", "NMAJ3", "MAJ5", "NMAJ5"}

#: Name of the no-op padding member (see ``plan.identity_plan``).
IDENTITY_NAME = "__pad__"


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledOp:
    """One fused pass: all same-type gates of one level, batched.

    ``inputs[j][i]`` is the node feeding input position ``j`` of the i-th
    batched gate; ``outputs[i]`` its output node; ``gids[i]`` the originating
    gate id (used to key per-gate fault-injection streams).  For ``MUX3``,
    ``gids[i]`` is the id of the root NAND of the fused 4-gate group.

    ``neg[j]`` complements input position ``j`` of every batched gate before
    the base op is applied — how absorbed lone NOT gates survive inside their
    consuming pass (``()`` means no complemented inputs).  Gates only batch
    with same-(op, neg) peers, so the mask is pass-wide.

    ``slots``/``free_after`` are filled by the ``liveness`` pipeline stage:
    ``slots[i]`` is the scratch-pool slot (in ``[0, plan.max_live)``) holding
    ``outputs[i]``, and ``free_after`` lists the node names whose last use is
    this pass — the executor drops them from its environment once the pass
    has run, and the megakernel recycles their slots from the next pass on.
    """

    op: str
    gids: tuple[int, ...]
    inputs: tuple[tuple[str, ...], ...]   # arity x n_batched
    outputs: tuple[str, ...]
    neg: tuple[bool, ...] = ()            # per-input complement mask
    slots: tuple[int, ...] = ()           # scratch slot per batched output
    free_after: tuple[str, ...] = ()      # nodes dead once this pass ran

    @property
    def n_batched(self) -> int:
        return len(self.outputs)


@dataclasses.dataclass(frozen=True)
class StreamTable:
    """Static layout of a plan's PI streams for one batched SNG pass.

    Row ``i`` describes one non-state PI: its node name, where its value
    comes from (``value_keys[i]`` into the caller's values dict, else
    ``const_values[i]``), and its fixed key-lane index ``lanes[i]``.  Lanes
    are assigned per plan — correlation groups (sorted by group name, members
    in declaration order) take lanes ``0..n_groups-1`` with every member of a
    group *sharing* its lane (shared uniforms => XOR decodes exact |a-b|),
    then the uncorrelated singles take one fresh lane each in declaration
    order.  The lane assignment mirrors the legacy per-PI key-split order, so
    the two disciplines differ only in how randomness is derived, not in
    which PI is "first".
    """

    names: tuple[str, ...]
    value_keys: tuple[str | None, ...]
    const_values: tuple[float | None, ...]
    lanes: tuple[int, ...]
    n_groups: int

    @property
    def n_rows(self) -> int:
        return len(self.names)


def build_stream_table(pis) -> StreamTable:
    """Lay out the stream table for a PI sequence (see ``StreamTable``)."""
    groups: dict[str, list[PrimaryInput]] = {}
    singles: list[PrimaryInput] = []
    for pi in pis:
        if pi.kind == PIKind.STATE:
            continue
        if pi.corr_group is not None:
            groups.setdefault(pi.corr_group, []).append(pi)
        else:
            singles.append(pi)
    rows: list[tuple[PrimaryInput, int]] = []
    for g, (_, gpis) in enumerate(sorted(groups.items())):
        rows.extend((pi, g) for pi in gpis)
    rows.extend((pi, len(groups) + k) for k, pi in enumerate(singles))
    return StreamTable(
        names=tuple(pi.name for pi, _ in rows),
        value_keys=tuple(pi.value_key for pi, _ in rows),
        const_values=tuple(pi.const_value for pi, _ in rows),
        lanes=tuple(lane for _, lane in rows),
        n_groups=len(groups),
    )


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """A netlist lowered to leveled, type-batched fused passes.

    ``eq=False``: plans are interned in the structure-keyed cache, so
    identity equality/hash is both correct and cheap as a jit static arg.

    ``aliases`` maps every *observable* node (primary output / state driver)
    elided by BUFF elision or CSE to the surviving node computing the
    identical stream; the executor re-exposes them in its node environment.
    Non-observable elided nodes need no alias — every use was rewritten to
    the survivor at compile time.  ``stream_table`` is the batched SNG
    layout of the plan's PI streams (see ``StreamTable``).

    ``serial`` is a process-wide monotone compile stamp: it gives plans a
    deterministic canonical order (bank templates sort members by it) without
    hashing structures on the serving hot path.

    ``max_live``/``pi_slots`` come from the ``liveness`` pipeline stage:
    ``max_live`` is the peak number of simultaneously-live node streams under
    the plan's pass order (the scratch-pool size a register-allocation-style
    executor needs, vs ``naive_live`` for keeping every node resident), and
    ``pi_slots[i]`` is the scratch slot assigned to ``pis[i]`` (``-1`` for a
    PI no pass reads and no output re-exposes — never materialized).

    ``schedule`` is the Algorithm-1 ``scheduler.Schedule`` of the plan's
    fused passes (pipeline stage "schedule"): each pass maps to one SIMD
    V_SL drive over the subarray, so ``schedule.logic_cycles`` prices the
    plan's in-memory cycle cost with the paper's one-op-per-row rule and
    ``scheduler.input_init_cycles(plan)`` its SBG input-initialization cost.
    ``arch.evaluate_bank_plan`` consumes it for scheduled cycle pricing.
    """

    name: str
    pis: tuple[PrimaryInput, ...]
    n_gates: int                                  # original gate count
    levels: tuple[tuple[CompiledOp, ...], ...]
    outputs: tuple[str, ...]
    state_pis: tuple[str, ...]
    state_drivers: tuple[str, ...]
    state_inits: tuple[float, ...]
    fused: bool
    n_fused_mux: int
    stream_table: StreamTable
    aliases: tuple[tuple[str, str], ...] = ()     # elided node -> survivor
    n_fused_xor: int = 0
    n_buff_elided: int = 0
    n_cse_elided: int = 0
    n_fused_and: int = 0
    n_not_absorbed: int = 0
    serial: int = -1
    schedule: Any = None                          # scheduler.Schedule | None
    max_live: int = 0                             # liveness: peak live nodes
    pi_slots: tuple[int, ...] = ()                # liveness: slot per PI

    @property
    def is_sequential(self) -> bool:
        return bool(self.state_pis)

    @property
    def is_identity(self) -> bool:
        """True for the no-op padding member (no PIs, gates, or outputs)."""
        return (not self.pis and not self.n_gates and not self.outputs
                and not self.state_pis)

    @property
    def n_passes(self) -> int:
        """Fused passes executed per evaluation (vs n_gates for the
        interpreter) — the compile-time speedup headline."""
        return sum(len(level) for level in self.levels)

    @property
    def n_elided(self) -> int:
        """Nodes removed from the pass schedule by BUFF elision and CSE."""
        return self.n_buff_elided + self.n_cse_elided

    @property
    def naive_live(self) -> int:
        """Node streams a keep-everything executor holds live at once (every
        PI plus every pass output) — the baseline ``max_live`` is measured
        against when pricing scratch occupancy."""
        return len(self.pis) + sum(cop.n_batched
                                   for level in self.levels for cop in level)

    def stream_pi_names(self) -> tuple[str, ...]:
        """Non-state PIs, in declaration order (the streams the executor
        generates; state PIs are carried by the sequential scan)."""
        return tuple(p.name for p in self.pis if p.kind != PIKind.STATE)


def member_prefix(index: int) -> str:
    """Node-namespace prefix for bank member ``index`` ("b3/out" etc.)."""
    return f"b{index}/"


@dataclasses.dataclass(frozen=True, eq=False)
class BankPlan:
    """N member plans merged for bank-level execution.

    Combinational members merge into one word-parallel plan (``comb``);
    sequential members merge into one plan run as a single scan (``seq``) —
    mixing them would re-execute combinational logic per bitstream bit.
    ``comb_members`` / ``seq_members`` hold the caller-order member indices of
    each group, in merge order (ascending), which is also the order of the
    per-member flat fault-key blocks (see ``executor`` bank dispatch).
    """

    name: str
    members: tuple[ExecutionPlan, ...]
    comb: ExecutionPlan | None
    seq: ExecutionPlan | None
    comb_members: tuple[int, ...]
    seq_members: tuple[int, ...]
    #: Process-wide monotone build stamp (like ExecutionPlan.serial): a
    #: stable identity token that — unlike id() — can never alias a
    #: garbage-collected bank after cache eviction.
    serial: int = -1

    @property
    def n_members(self) -> int:
        return len(self.members)

    @property
    def n_identity_members(self) -> int:
        """Slots filled by the no-op identity padding plan."""
        return sum(1 for m in self.members if m.is_identity)

    @property
    def n_passes(self) -> int:
        """Fused passes per bank-wide evaluation (the merged headline)."""
        return (self.comb.n_passes if self.comb else 0) + \
               (self.seq.n_passes if self.seq else 0)

    @property
    def n_passes_looped(self) -> int:
        """Passes a per-member dispatch loop would execute (the baseline)."""
        return sum(m.n_passes for m in self.members)
